"""Static model verifier (ISSUE 7): mutation suite — every rule must catch
one deliberately-broken artifact — plus malformed-input error reporting,
entry-point wiring, the shipped-matrix zero-error contract, and the
verifier-driven fixes to planner.enumerate_plans / memory_per_device."""
import dataclasses
import json
import subprocess
import sys
import warnings

import pytest

import repro.verify as verify_cli
from repro.configs import ARCHS
from repro.core import hardware as hw
from repro.core import planner, result_cache, verify
from repro.core import simulator as sim_mod
from repro.core.evaluator import Evaluator
from repro.core.fusion import FULL, FusionPolicy, fuse
from repro.core.graph import Plan, build_model
from repro.core.ir import (CollectiveSpec, ElementwiseSpec, FusedMatmulSpec,
                           Graph, MatmulSpec, Node, NormSpec, SoftmaxSpec)
from repro.core.precision import (DEFAULT, FP16, FP32, INT8, DType,
                                  PrecisionPolicy)
from repro.core.schedule import schedule_graph
from repro.core.study import Case, Study
from repro.core.verify import (Diagnostic, VerificationError,
                               VerificationWarning)
from repro.core.workload import Trace, TrafficWorkload, Workload

QWEN2 = ARCHS["qwen2-0.5b"]          # 14 heads, 2 KV heads, 24 layers
STABLE = ARCHS["stablelm-1.6b"]      # 32 heads MHA


def _node(spec, name="op", repeat=1, deps=None):
    return Node(spec, name, repeat, deps)


def _chain(*specs):
    return Graph(tuple(_node(s, f"n{i}") for i, s in enumerate(specs)))


def _rules_of(diags):
    return {d.rule for d in diags}


# ---------------------------------------------------------------------------
# mutation suite: one deliberately-broken artifact per registered rule
# ---------------------------------------------------------------------------

def _graph_mutants():
    """rule id -> a Graph (or (Graph, device)) that must trigger it."""
    mm = MatmulSpec(8, 8, 8)
    a100 = hw.nvidia_a100()

    class NotASpec:                       # not a member of ir.OpSpec
        pass

    flash_producer = FusedMatmulSpec(
        dataclasses.replace(mm, bytes_out=0), (SoftmaxSpec(8, 8),),
        stream_out=True)
    return {
        "graph.producers": Graph((_node(mm, deps=(5,)),)),
        "graph.acyclic": Graph((_node(mm, "a", deps=(1,)),
                                _node(mm, "b", deps=(0,)))),
        "graph.topo-order": Graph((_node(mm, "a", deps=(1,)),
                                   _node(mm, "b", deps=()))),
        "graph.unconsumed": Graph((_node(mm, "dead", deps=()),
                                   _node(mm, "src", deps=()),
                                   _node(mm, "sink", deps=(1,)))),
        "graph.resource": Graph((_node(NotASpec()),)),
        "graph.values": _chain(MatmulSpec(0, 8, 8)),
        "graph.accumulator": _chain(
            MatmulSpec(8, 8, 8, bytes_a=2, bytes_b=2, bytes_acc=1)),
        "graph.mac-scale": _chain(MatmulSpec(8, 8, 8, mac_scale=3.0)),
        # bytes_out NOT rescaled to the epilogue's output (the fusion-
        # rewrite bug class the dataflow rule exists for): the fused kernel
        # claims 2 B/elem writes while its final epilogue emits 1 B/elem
        "graph.dataflow": _chain(
            FusedMatmulSpec(MatmulSpec(8, 8, 8, bytes_out=2),
                            (SoftmaxSpec(8, 8, bytes_out=1),))),
        # fp32 operands on the a100's fp16 systolic datapath
        "graph.datapath": (_chain(
            MatmulSpec(8, 8, 8, bytes_a=4, bytes_b=4, bytes_acc=4)), a100),
        # keep the flash pair handy for the stream-side assertions below
        "_flash_no_consumer": Graph((_node(flash_producer, "flash"),)),
    }


GRAPH_RULES = sorted(r for r in verify.RULES if r.startswith("graph."))


@pytest.mark.parametrize("rule_id", GRAPH_RULES)
def test_mutation_graph_rules(rule_id):
    mutants = _graph_mutants()
    art = mutants[rule_id]
    g, dev = art if isinstance(art, tuple) else (art, None)
    assert rule_id in _rules_of(verify.graph_diagnostics(g, dev)), \
        f"{rule_id} did not catch its mutant"


def test_mutation_flash_stream_pairing():
    """stream_out without a consumer, and bytes_a=0 without a streamer,
    are both dataflow errors."""
    mutants = _graph_mutants()
    diags = verify.graph_diagnostics(mutants["_flash_no_consumer"])
    assert any(d.rule == "graph.dataflow" and d.severity == "error"
               and "no consumer" in d.message for d in diags)
    orphan = _chain(MatmulSpec(8, 8, 8, bytes_a=0))
    diags = verify.graph_diagnostics(orphan)
    assert any(d.rule == "graph.dataflow" and "bytes_a=0" in d.message
               for d in diags)


def test_mutation_dataflow_conservation_warn():
    """A softmax reading more elements than its sole producer emits is the
    bytes-from-nowhere warn; a norm in the same seat is only an info
    (block-boundary norms may open a new stream)."""
    mm = MatmulSpec(8, 8, 8)                    # outputs 64 elements
    sm = Graph((_node(mm, "gemm"), _node(SoftmaxSpec(64, 64), "sm")))
    diags = [d for d in verify.graph_diagnostics(sm)
             if d.rule == "graph.dataflow"]
    assert diags and diags[0].severity == "warn"
    nm = Graph((_node(mm, "gemm"), _node(NormSpec("rmsnorm", 64, 64), "ln")))
    diags = [d for d in verify.graph_diagnostics(nm)
             if d.rule == "graph.dataflow"]
    assert diags and diags[0].severity == "info"


def _plan_mutants():
    """rule id -> (system, cfg, plan, expected severity)."""
    dgx4 = hw.dgx_a100(4)
    moe = dataclasses.replace(QWEN2, n_heads=16, n_kv_heads=16, n_experts=3)
    return {
        "plan.devices": (dgx4, STABLE, Plan(tp=8), "error"),
        "plan.tp-heads": (dgx4, QWEN2, Plan(tp=4), "error"),
        "plan.tp-kv-heads": (dgx4, dataclasses.replace(QWEN2, n_heads=16),
                             Plan(tp=4), "info"),
        "plan.pp-layers": (hw.dgx_a100(32), STABLE, Plan(pp=32), "error"),
        "plan.ep-experts": (dgx4, moe, Plan(dp=4, ep=2), "error"),
        "plan.memory": (hw.dgx_a100(1), ARCHS["grok-1-314b"], Plan(),
                        "error"),
    }


PLAN_RULES = sorted(r for r in verify.RULES if r.startswith("plan."))


@pytest.mark.parametrize("rule_id", PLAN_RULES)
def test_mutation_plan_rules(rule_id):
    system, cfg, plan, sev = _plan_mutants()[rule_id]
    diags = verify.plan_diagnostics(system, cfg, plan)
    hit = [d for d in diags if d.rule == rule_id]
    assert hit, f"{rule_id} did not catch its mutant"
    assert hit[0].severity == sev


def _policy_mutants():
    bad_dtype = DType("odd3", 16, 3.0, 1.0)     # non-pow2 issue rate
    return {
        "policy.accumulator": (PrecisionPolicy(accumulator=INT8), None),
        "policy.mac-scale": (PrecisionPolicy(weights=bad_dtype,
                                             activations=bad_dtype), None),
        "policy.datapath": (DEFAULT,
                            hw.with_mac_dtype(hw.nvidia_a100(), "int8")),
    }


POLICY_RULES = sorted(r for r in verify.RULES if r.startswith("policy."))


@pytest.mark.parametrize("rule_id", POLICY_RULES)
def test_mutation_policy_rules(rule_id):
    policy, device = _policy_mutants()[rule_id]
    diags = verify.policy_diagnostics(policy, device)
    assert rule_id in _rules_of(diags), f"{rule_id} missed its mutant"


def _good_schedule():
    g = fuse(build_model(STABLE, Plan(tp=2, dp=2), 1, 64, kv_len=64), FULL)
    lats = [1e-6 * (i % 5 + 1) * n.repeat for i, n in enumerate(g)]
    return g, lats, schedule_graph(g, lats)


def _mutate_slot(sch, i, **kw):
    slots = list(sch.slots)
    slots[i] = dataclasses.replace(slots[i], **kw)
    return dataclasses.replace(sch, slots=slots)


SCHED_RULES = sorted(r for r in verify.RULES if r.startswith("schedule."))


@pytest.mark.parametrize("rule_id", SCHED_RULES)
def test_mutation_schedule_rules(rule_id):
    g, lats, sch = _good_schedule()
    assert verify.schedule_diagnostics(g, lats, sch) == []
    # find a node with a producer to perturb
    victim = next(i for i, n in enumerate(g.nodes)
                  if i > 0 and n.deps is None or (n.deps and len(n.deps)))
    if rule_id == "schedule.deps":
        bad = _mutate_slot(sch, victim, start=-1.0, end=-1.0 +
                           sch.slots[victim].duration)
    elif rule_id == "schedule.exclusive":
        # clone node 1 onto node 0's busy window (same resource)
        s0 = sch.slots[0]
        twin = next(i for i, s in enumerate(sch.slots[1:], 1)
                    if s.resource == s0.resource)
        bad = _mutate_slot(sch, twin, start=s0.start,
                           end=s0.start + sch.slots[twin].duration)
    elif rule_id == "schedule.makespan":
        bad = dataclasses.replace(sch, makespan=sch.makespan * 10)
    elif rule_id == "schedule.pipelining":
        bad = _mutate_slot(sch, victim,
                           end=sch.slots[victim].end
                           + sch.slots[victim].duration + 1.0)
    else:   # schedule.busy
        busy = dict(sch.busy)
        busy["compute"] = busy.get("compute", 0.0) + 1.0
        bad = dataclasses.replace(sch, busy=busy)
    assert rule_id in _rules_of(verify.schedule_diagnostics(g, lats, bad)), \
        f"{rule_id} did not catch its mutant"


def test_mutation_registry_coverage(monkeypatch):
    """Dropping a sample spec breaks the ir.resource_of coverage contract."""
    assert verify.registry_diagnostics() == []
    monkeypatch.setattr(verify, "_SAMPLE_SPECS", verify._SAMPLE_SPECS[1:])
    diags = verify.registry_diagnostics()
    assert any(d.rule == "ir.resource-coverage" and "MatmulSpec"
               in d.message for d in diags)


def test_every_rule_has_a_mutant():
    """The mutation suite covers the complete registry — adding a rule
    without a mutant fails here, not silently."""
    covered = (set(_graph_mutants()) | set(_plan_mutants())
               | set(_policy_mutants()) | set(SCHED_RULES))
    assert set(verify.RULES) <= covered


# ---------------------------------------------------------------------------
# modes, errors, warnings
# ---------------------------------------------------------------------------

def test_verification_error_lists_all_diagnostics():
    g = Graph((_node(MatmulSpec(0, 8, 8), "a", deps=(1,)),
               _node(MatmulSpec(8, 8, 8, mac_scale=3.0), "b", deps=(0,))))
    with pytest.raises(VerificationError) as exc:
        verify.verify_graph(g, mode="error")
    e = exc.value
    assert len(e.diagnostics) >= 3          # cycle + dims + mac_scale
    rules = _rules_of(e.diagnostics)
    assert {"graph.acyclic", "graph.values", "graph.mac-scale"} <= rules
    # the message carries every finding, sorted errors-first
    assert str(e).count("\n") >= 3
    sevs = [d.severity for d in e.diagnostics]
    assert sevs == sorted(sevs, key=verify.SEVERITIES.index)


def test_warn_mode_warns_and_returns():
    g = _chain(MatmulSpec(8, 8, 8, mac_scale=3.0))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        diags = verify.verify_graph(g, mode="warn")
    assert [d.rule for d in diags] == ["graph.mac-scale"]
    assert any(issubclass(w.category, VerificationWarning) for w in rec)


def test_off_mode_skips():
    g = _chain(MatmulSpec(0, 0, 0))
    assert verify.verify_graph(g, mode="off") == []


def test_env_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    assert verify.resolve_mode(None) == "warn"
    monkeypatch.setenv("REPRO_VERIFY", "error")
    assert verify.resolve_mode(None) == "error"
    assert verify.resolve_mode("off") == "off"      # explicit beats env
    with pytest.raises(ValueError):
        verify.resolve_mode("loud")


def test_diagnostic_str():
    d = Diagnostic("graph.acyclic", "error", "boom", "node 3 ('x')", "fix")
    assert str(d) == "error[graph.acyclic] @ node 3 ('x'): boom (hint: fix)"


# ---------------------------------------------------------------------------
# entry-point wiring: Evaluator / Study / simulator
# ---------------------------------------------------------------------------

def test_evaluator_rejects_malformed_graph_cleanly():
    """A cyclic graph fails as ONE VerificationError before any mapper
    work — not a deep stack trace from scheduling or the mapper."""
    ev = Evaluator(hw.dgx_a100(4), verify="error")
    bad = Graph((_node(MatmulSpec(8, 8, 8), "a", deps=(1,)),
                 _node(MatmulSpec(8, 8, 8), "b", deps=(0,))))
    with pytest.raises(VerificationError) as exc:
        ev.evaluate(bad, overlap=True)
    assert "graph.acyclic" in str(exc.value)


def test_evaluator_verifies_each_graph_once():
    with result_cache.disabled():
        ev = Evaluator(hw.dgx_a100(4), verify="error")
        g = fuse(build_model(STABLE, Plan(tp=2, dp=2), 1, 32, kv_len=32),
                 FULL)
        ev.evaluate(g, overlap=True)        # lints + certificate-checks
        assert g in ev._verified
        ev.evaluate(g, overlap=True)        # second pass: memoized lint
        assert len(ev._verified) == 1


def test_evaluator_off_mode_does_not_lint():
    ev = Evaluator(hw.dgx_a100(4), verify="off")
    assert ev.verify_mode == "off"
    with result_cache.disabled():
        ev.evaluate(_chain(MatmulSpec(8, 8, 8, mac_scale=4.0)))
    assert not ev._verified


def test_study_error_mode_rejects_infeasible_plan():
    with result_cache.disabled():
        study = Study(cases=[Case(hw.dgx_a100(4), QWEN2, Plan(tp=4),
                                  Workload(1, 64, 4))], verify="error")
        with pytest.raises(VerificationError) as exc:
            study.run()
        assert "plan.tp-heads" in str(exc.value)


def test_study_warn_mode_completes():
    with result_cache.disabled():
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            res = Study(cases=[Case(hw.dgx_a100(4), QWEN2, Plan(tp=4),
                                    Workload(1, 32, 2))],
                        verify="warn").run()
    assert res[0].latency > 0
    assert any(issubclass(w.category, VerificationWarning) for w in rec)


def test_study_clean_grid_in_error_mode():
    with result_cache.disabled():
        res = Study(cases=[Case(hw.dgx_a100(4), QWEN2, Plan(tp=2, dp=2),
                                Workload(1, 32, 2))], verify="error").run()
    assert res[0].latency > 0


def test_simulator_error_mode_rejects_infeasible_plan():
    trace = Trace.poisson(4, rate=100.0, in_len=32, out_len=4, seed=0)
    tw = TrafficWorkload.from_trace(trace, slots=2)
    with result_cache.disabled():
        with pytest.raises(VerificationError) as exc:
            sim_mod.simulate(hw.dgx_a100(4), QWEN2, Plan(tp=4), tw,
                             verify="error")
    assert "plan.tp-heads" in str(exc.value)


# ---------------------------------------------------------------------------
# shipped-matrix contract + the fixes the verifier drove (satellite 1)
# ---------------------------------------------------------------------------

def test_shipped_matrix_has_zero_errors_and_warns():
    """Every shipped config/plan/policy/fusion combination lints clean
    (info-severity notes are allowed: whisper's encoder seam, idle-device
    plans, GQA KV replication)."""
    report = verify_cli.lint_all(all_configs=True)
    bad = [r for r in report if r["severity"] in ("error", "warn")]
    assert bad == [], bad


def test_enumerate_plans_emits_no_illegal_plans():
    """The verifier caught enumerate_plans emitting head-dropping tp splits
    (qwen2's 14 heads at tp=4 modeled 12) and pp>n_layers stages (whisper's
    4 layers at pp=8 priced phantom layers). Both are now filtered; the
    diagnostics that caught them must never fire on the enumeration."""
    for system in (hw.dgx_a100(4), hw.tpu_v5e_pod(16)):
        for cfg in ARCHS.values():
            for plan in planner.enumerate_plans(system, cfg):
                diags = verify.plan_diagnostics(system, cfg, plan,
                                                check_memory=False)
                firing = {d.rule for d in diags if d.severity == "error"}
                assert "plan.tp-heads" not in firing, (cfg.name, plan)
                assert "plan.pp-layers" not in firing, (cfg.name, plan)
                assert not firing, (cfg.name, plan, firing)


def test_gqa_kv_memory_shards_at_most_kv_heads_ways():
    """The plan.tp-kv-heads diagnostic exposed memory_per_device dividing
    KV by the full tp even when tp > n_kv_heads (ranks hold replicas).
    KV memory must stop shrinking once tp exceeds the KV head count."""
    from repro.core.inference_model import memory_per_device
    cfg = dataclasses.replace(QWEN2, n_heads=16)    # 16 heads, 2 KV heads

    def kv_delta(tp):
        # KV bytes for 1024 extra tokens of context: the memory delta minus
        # the (tp-sharded) activation term's growth
        p = Plan(tp=tp)
        d = memory_per_device(cfg, p, 1, 2048) \
            - memory_per_device(cfg, p, 1, 1024)
        return d - 1024 * cfg.d_model * 2 * 4 / tp

    # tp=2 == n_kv_heads shards KV fully; tp=4 must NOT halve it again —
    # the extra ranks hold replicas (diagnostic plan.tp-kv-heads)
    assert kv_delta(4) == pytest.approx(kv_delta(2), rel=1e-9)
    assert kv_delta(4) == pytest.approx(
        1024 * cfg.kv_bytes_per_token(2) / 2, rel=1e-9)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_clean_matrix_and_json(tmp_path):
    out = tmp_path / "report.json"
    rc = verify_cli.main(["--all-configs", "--json", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["counts"]["error"] == 0
    assert doc["counts"]["warn"] == 0
    assert all(set(d) >= {"where", "rule", "severity", "message"}
               for d in doc["diagnostics"])


def test_cli_module_invocation():
    p = subprocess.run([sys.executable, "-m", "repro.verify"],
                       capture_output=True, text=True)
    assert p.returncode == 0, p.stderr
    assert "0 errors" in p.stdout


# ---------------------------------------------------------------------------
# typing smoke (satellite 2): annotations on the strict-checked core must
# at least resolve at runtime — mypy itself runs in CI
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("modname", ["ir", "schedule", "precision", "verify",
                                     "units", "mapper", "interconnect",
                                     "operators", "roofline"])
def test_core_annotations_resolve(modname):
    import importlib
    import typing
    mod = importlib.import_module(f"repro.core.{modname}")
    for name in getattr(mod, "__all__", None) or dir(mod):
        obj = getattr(mod, name)
        if isinstance(obj, type) and dataclasses.is_dataclass(obj):
            typing.get_type_hints(obj, include_extras=True)  # raises if broken
